"""Fused pad+project+whiten kernel + the serving fast path around it:
allclose parity vs the pure-jnp oracles (interpret mode on CPU), the
interpret-resolution policy, the register-time tile autotuner's cache
lifecycle (promote hits, eviction re-tunes), the Execution-aware registry
config hash, and the floor/ceiling regression-gate directions.  Marked
`kernels` — CI runs these in the dedicated kernels job."""

import dataclasses
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import execution as exe_mod
from repro.core import random_projection as rp
from repro.core.execution import PALLAS, XLA, Execution, resolve_interpret
from repro.dr import DRModel, EASIStage, RPStage
from repro.kernels import autotune, ops, ref
from repro.kernels.fused_transform import fused_transform
from repro.serve import BucketPolicy, DRService, ModelRegistry
from repro.serve.clock import VirtualClock
from repro.serve.registry import model_config_hash

jax.config.update("jax_enable_x64", False)

pytestmark = pytest.mark.kernels

REPO = Path(__file__).resolve().parent.parent


def _mk_ternary(key, p, m):
    return rp.sample_ternary(key, rp.RPConfig(m=m, p=p))


def _mk_b(key, n, p, dtype=jnp.float32):
    return jax.random.normal(key, (n, p), dtype)


# ---------------------------------------------------------------------------
# fused kernel vs oracle
# ---------------------------------------------------------------------------

FUSED_SHAPES = [
    # (rows, m, p, n) — paper scale, ragged rows, and non-aligned odd dims
    (8, 32, 16, 8),
    (13, 32, 16, 8),
    (64, 33, 17, 9),
    (200, 100, 40, 10),
    (5, 7, 3, 2),
    (1, 32, 16, 8),
]


class TestFusedKernel:
    @pytest.mark.parametrize("rows,m,p,n", FUSED_SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, rows, m, p, n, dtype):
        kx, kr, kb = jax.random.split(jax.random.PRNGKey(rows + 7 * m), 3)
        x = jax.random.normal(kx, (rows, m), dtype)
        r = _mk_ternary(kr, p, m)
        b = _mk_b(kb, n, p, dtype)
        got = fused_transform(x, r, b, scale=0.37, interpret=True)
        want = ref.fused_transform_ref(x, r, b, scale=0.37)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    @pytest.mark.parametrize(
        "blocks", [(8, 128, 128), (16, 256, 256), (512, 512, 512),
                   (32, 128, 512)])
    def test_block_shape_invariance(self, blocks):
        bm, bp, bk = blocks
        x = jax.random.normal(jax.random.PRNGKey(0), (40, 300), jnp.float32)
        r = _mk_ternary(jax.random.PRNGKey(1), 48, 300)
        b = _mk_b(jax.random.PRNGKey(2), 12, 48)
        got = fused_transform(x, r, b, block_m=bm, block_p=bp, block_k=bk,
                              interpret=True)
        want = ref.fused_transform_ref(x, r, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_exactness_on_integers(self):
        # Ternary R and small-integer x/B keep every product exact in fp32,
        # so the pad-and-mask plumbing must be bit-exact vs the oracle.
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-8, 8, (16, 64)), jnp.float32)
        r = _mk_ternary(jax.random.PRNGKey(2), 32, 64)
        b = jnp.asarray(rng.integers(-4, 4, (8, 32)), jnp.float32)
        got = fused_transform(x, r, b, interpret=True)
        want = ref.fused_transform_ref(x, r, b)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_vmap_over_ensemble_axis(self):
        # DREnsemble vmaps transform over stacked (R, B) — the kernel must
        # batch cleanly under vmap.
        k = 3
        kx, kr, kb = jax.random.split(jax.random.PRNGKey(9), 3)
        x = jax.random.normal(kx, (24, 32), jnp.float32)
        rs = jnp.stack([_mk_ternary(jax.random.fold_in(kr, i), 16, 32)
                        for i in range(k)])
        bs = jax.random.normal(kb, (k, 8, 16), jnp.float32)
        got = jax.vmap(
            lambda r, b: fused_transform(x, r, b, interpret=True))(rs, bs)
        want = jnp.stack([ref.fused_transform_ref(x, rs[i], bs[i])
                          for i in range(k)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_ops_wrapper_resolves_execution(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 32), jnp.float32)
        r = _mk_ternary(jax.random.PRNGKey(1), 16, 32)
        b = _mk_b(jax.random.PRNGKey(2), 8, 16)
        exe = dataclasses.replace(PALLAS, interpret=True)
        got = ops.fused_transform(x, r, b, execution=exe)
        want = ref.fused_transform_ref(x, r, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# interpret resolution: explicit pin > Execution policy > cached probe
# ---------------------------------------------------------------------------

class TestInterpretResolution:
    def test_explicit_pin_wins(self):
        assert resolve_interpret(True, Execution(interpret=False)) is True
        assert resolve_interpret(False, Execution(interpret=True)) is False

    def test_policy_pin_wins_over_probe(self):
        assert resolve_interpret(None, Execution(interpret=True)) is True
        assert resolve_interpret(None, Execution(interpret=False)) is False

    def test_probe_is_process_cached(self):
        exe_mod._probe_interpret.cache_clear()
        first = resolve_interpret()
        assert first is (jax.default_backend() != "tpu")
        assert resolve_interpret(None, None) is first
        assert exe_mod._probe_interpret.cache_info().currsize == 1
        # the second resolve hit the lru cache, not a fresh backend probe
        assert exe_mod._probe_interpret.cache_info().hits >= 1

    def test_constants_leave_mode_unpinned(self):
        assert XLA.interpret is None
        assert PALLAS.interpret is None
        assert PALLAS.resolved_interpret() is resolve_interpret()


# ---------------------------------------------------------------------------
# model-level parity: pallas fused path ≡ stage-wise XLA reference
# ---------------------------------------------------------------------------

def _pair_model(personality, backend, m=32, p=16, n=8, block=4):
    easi = getattr(EASIStage, personality)(p, n, mu=1e-3)
    return DRModel(stages=(RPStage(m, p), easi), block_size=block,
                   execution=Execution(backend=backend))


class TestModelFusedPath:
    @pytest.mark.parametrize("personality", ["whiten", "rotation", "full"])
    @pytest.mark.parametrize("rows", [4, 13, 32])
    def test_transform_parity_all_personalities(self, personality, rows):
        xla_m = _pair_model(personality, "xla")
        pal_m = _pair_model(personality, "pallas")
        state = xla_m.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (rows, 32), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(pal_m.transform(state, x)),
            np.asarray(xla_m.transform(state, x)), rtol=1e-4, atol=1e-4)

    def test_three_stage_cascade_parity(self):
        # fused pair covers stages 0-1; the trailing EASI runs stage-wise
        stages = (RPStage(32, 16), EASIStage.rotation(16, 8),
                  EASIStage.whiten(8, 4))
        xla_m = DRModel(stages=stages, execution=XLA)
        pal_m = DRModel(stages=stages, execution=PALLAS)
        state = xla_m.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (19, 32), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(pal_m.transform(state, x)),
            np.asarray(xla_m.transform(state, x)), rtol=1e-4, atol=1e-4)

    def test_update_parity_easi_kernel(self):
        # streamed updates fold through kernels.ops.easi_update under pallas
        xla_m = _pair_model("full", "xla")
        pal_m = _pair_model("full", "pallas")
        st_x = xla_m.init(jax.random.PRNGKey(0))
        st_p = st_x
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, 32), jnp.float32)
        for blk in x:
            st_x = xla_m.update(st_x, blk)
            st_p = pal_m.update(st_p, blk)
        np.testing.assert_allclose(
            np.asarray(st_p.stages[1]), np.asarray(st_x.stages[1]),
            rtol=5e-4, atol=5e-5)

    def test_serve_and_update_parity(self):
        outs = {}
        for backend in ("xla", "pallas"):
            model = _pair_model("rotation", backend)
            svc = DRService(buckets=BucketPolicy(min_bucket=4, max_bucket=16),
                            clock=VirtualClock())
            svc.register("m", model, model.init(jax.random.PRNGKey(0)))
            ys = []
            for i in range(5):
                blk = jax.random.normal(jax.random.PRNGKey(10 + i), (4, 32),
                                        jnp.float32)
                ys.append(np.asarray(svc.serve_and_update("m", blk)))
            svc.promote("m")
            probe = jax.random.normal(jax.random.PRNGKey(99), (7, 32),
                                      jnp.float32)
            outs[backend] = (np.concatenate(ys),
                             np.asarray(svc.transform("m", probe)))
        np.testing.assert_allclose(outs["pallas"][0], outs["xla"][0],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(outs["pallas"][1], outs["xla"][1],
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# autotuner: sweep dedupe, tie-breaking, and the cache lifecycle
# ---------------------------------------------------------------------------

class TestAutotune:
    def test_paper_scale_sweep_collapses_to_one(self):
        # m=32, p=16, bucket 64: every candidate clamps to the same
        # effective tiles, so tuning costs zero extra compiles.
        assert len(autotune.candidates(64, 16, 32)) == 1

    def test_first_candidate_leads_and_dedupes(self):
        mine = autotune.TileConfig(64, 128, 128)
        cands = autotune.candidates(1024, 200, 600, first=mine)
        assert cands[0] == mine
        assert len(cands) == len(set(c.effective(1024, 200, 600)
                                     for c in cands))
        assert len(cands) > 1

    def test_tie_keeps_first_candidate(self):
        built = []

        def build(tiles):
            built.append(tiles)
            return lambda v: v + 1.0
        cands = (autotune.TileConfig(8, 128, 128),
                 autotune.TileConfig(16, 128, 128))
        prog = autotune.tune(cands, build, (jnp.zeros(4),),
                             timer=lambda: 0.0)  # virtual clock: all tie
        assert prog.tiles == cands[0]
        assert built == list(cands)
        assert set(prog.timings_ms) == set(cands)

    def test_single_candidate_skips_timing(self):
        built = []

        def build(tiles):
            built.append(tiles)
            return lambda v: v
        prog = autotune.tune((autotune.TileConfig(),), build, (jnp.zeros(2),),
                             timer=lambda: 0.0)
        assert built == [autotune.TileConfig()]
        assert prog.timings_ms == {}


class TestServiceAutotuneCache:
    def _svc(self, cache_size=32, max_bucket=8):
        model = _pair_model("rotation", "pallas")
        svc = DRService(buckets=BucketPolicy(min_bucket=4,
                                             max_bucket=max_bucket),
                        compile_cache_size=cache_size, clock=VirtualClock())
        state = model.init(jax.random.PRNGKey(0))
        svc.register("m", model, state)
        return svc, model, state

    def test_register_tunes_every_bucket(self):
        svc, model, state = self._svc(max_bucket=16)   # buckets 4, 8, 16
        assert svc.metrics()["autotunes"] == 3
        assert svc.cache.misses == 3
        snap = svc.registry.get("m")
        prog = svc._transform_fn(snap, 8, jnp.dtype(jnp.float32))
        assert isinstance(prog, autotune.TunedProgram)
        # collapsed paper-scale sweep keeps the policy's own tiles
        exe = model.execution
        assert prog.tiles == autotune.TileConfig(
            exe.tmm_block_m, exe.tmm_block_p, exe.tmm_block_k)
        assert svc.metrics()["autotunes"] == 3         # that was a cache hit

    def test_promote_never_retunes(self):
        svc, model, state = self._svc()                # buckets 4, 8
        assert svc.metrics()["autotunes"] == 2
        for i in range(3):
            svc.serve_and_update(
                "m", jax.random.normal(jax.random.PRNGKey(i), (4, 32)))
        m0 = svc.cache.misses          # transform buckets + the tws program
        svc.promote("m")                               # same chash → cache hit
        svc.transform("m", jnp.ones((8, 32), jnp.float32))
        assert svc.metrics()["autotunes"] == 2
        assert svc.cache.misses == m0

    def test_eviction_drops_program_and_tiles_then_retunes(self):
        svc, model, state = self._svc(cache_size=1)    # buckets 4, 8
        assert svc.metrics()["autotunes"] == 2         # bucket-4 entry evicted
        assert len(svc.cache) == 1
        svc.transform("m", jnp.ones((4, 32), jnp.float32))  # rebuild → re-tune
        assert svc.metrics()["autotunes"] == 3
        assert svc.cache.misses == 3

    def test_xla_register_does_not_tune(self):
        model = _pair_model("rotation", "xla")
        svc = DRService(buckets=BucketPolicy(min_bucket=4, max_bucket=8),
                        clock=VirtualClock())
        svc.register("m", model, model.init(jax.random.PRNGKey(0)))
        assert svc.metrics()["autotunes"] == 0
        assert svc.cache.misses == 0                   # XLA compiles lazily


# ---------------------------------------------------------------------------
# registry config hash folds in the Execution backend
# ---------------------------------------------------------------------------

class _ReprBlindModel:
    """A model whose repr hides its execution policy — the registry hash
    must still distinguish backends (it hashes the policy explicitly, not
    whatever the model's repr happens to include)."""

    def __init__(self, execution):
        self.execution = execution

    def __repr__(self):
        return "_ReprBlindModel()"


class TestRegistryExecutionHash:
    def test_backend_changes_model_config_hash(self):
        stages = (RPStage(32, 16), EASIStage.rotation(16, 8))
        h_xla = model_config_hash(DRModel(stages=stages, execution=XLA))
        h_pal = model_config_hash(DRModel(stages=stages, execution=PALLAS))
        assert h_xla != h_pal

    def test_hash_is_repr_independent(self):
        a = _ReprBlindModel(XLA)
        b = _ReprBlindModel(PALLAS)
        assert repr(a) == repr(b)
        assert model_config_hash(a) != model_config_hash(b)

    def test_register_rejects_silent_backend_swap(self):
        reg = ModelRegistry()
        reg.register("m", _ReprBlindModel(XLA), {"w": 0})
        with pytest.raises(ValueError, match="replace=True"):
            reg.register("m", _ReprBlindModel(PALLAS), {"w": 0})
        reg.register("m", _ReprBlindModel(PALLAS), {"w": 0}, replace=True)
        assert reg.get("m").chash == model_config_hash(_ReprBlindModel(PALLAS))


# ---------------------------------------------------------------------------
# regression gate directions (floor vs ceiling)
# ---------------------------------------------------------------------------

class TestRegressionGateDirections:
    def _run(self, tmp_path, measured, baseline, *extra):
        mf = tmp_path / "measured.json"
        bf = tmp_path / "baseline.json"
        mf.write_text(json.dumps(measured))
        bf.write_text(json.dumps(baseline))
        return subprocess.run(
            [sys.executable, str(REPO / "benchmarks" / "check_regression.py"),
             str(mf), str(bf), *extra],
            capture_output=True, text=True)

    BASE = {"r": {"util": {"value": 0.01, "gate": "floor"}, "lat": 100.0}}

    def test_floor_passes_above_and_at_limit(self, tmp_path):
        res = self._run(tmp_path,
                        [{"name": "r", "util": 0.005, "lat": 90.0}], self.BASE)
        assert res.returncode == 0, res.stdout + res.stderr

    def test_floor_fails_below_limit(self, tmp_path):
        res = self._run(tmp_path,
                        [{"name": "r", "util": 0.004, "lat": 90.0}], self.BASE)
        assert res.returncode == 1
        assert "util" in res.stderr and "floor" in res.stderr

    def test_ceiling_still_fails_high(self, tmp_path):
        res = self._run(tmp_path,
                        [{"name": "r", "util": 0.02, "lat": 900.0}], self.BASE)
        assert res.returncode == 1
        assert "lat" in res.stderr

    def test_only_filters_baseline_rows(self, tmp_path):
        base = dict(self.BASE, other={"x": 1.0})
        res = self._run(tmp_path, [{"name": "r", "util": 0.02, "lat": 90.0}],
                        base, "--only", "r")
        assert res.returncode == 0, res.stdout + res.stderr
        res = self._run(tmp_path, [{"name": "r", "util": 0.02, "lat": 90.0}],
                        base, "--only", "nope")
        assert res.returncode == 2
