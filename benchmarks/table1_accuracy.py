"""Paper Table I benchmark: Waveform-V2 classification accuracy per DR config.

Single-seed, reduced-epoch variant of examples/waveform_repro.py (the full
3-seed protocol is archived in EXPERIMENTS.md §Paper-parity).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp

from repro.configs import waveform_paper as wp
from repro.core import pipeline
from repro.data import waveform


def run(fast: bool = True):
    (xtr, ytr), (xte, yte) = waveform.paper_split(seed=0)
    xtr, ytr, xte, yte = map(jnp.asarray, (xtr, ytr, xte, yte))
    rows = []
    for name, cfg in wp.TABLE1_ROWS.items():
        c = dataclasses.replace(cfg, seed=0)
        if fast:
            c = dataclasses.replace(c, dr_epochs=max(1, c.dr_epochs // 4), head_epochs=15)
        t0 = time.perf_counter()
        model = pipeline.fit_two_stage(c, xtr, ytr)
        acc = pipeline.evaluate(model, xte, yte)
        dt = time.perf_counter() - t0
        rows.append((f"table1/{name}", dt * 1e6, f"acc={acc*100:.1f}%;paper={wp.PAPER_TABLE1[name]}"))
    return rows
