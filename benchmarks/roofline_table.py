"""Aggregate the dry-run JSONs into the §Roofline table (one row per cell)."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_reports(mesh: str = None):
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        out.append(r)
    return out


def run(fast: bool = True):
    rows = []
    for r in load_reports(mesh="single"):
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            r["step_time_bound"] * 1e6,
            f"dominant={r['dominant']};roofline={100*r['roofline_fraction']:.1f}%;"
            f"Tc={r['t_comp']:.4f};Tm={r['t_mem']:.4f};Tx={r['t_coll']:.4f};"
            f"MF/HLO={r['flops_ratio']:.3f}",
        ))
    if not rows:
        rows.append(("roofline/none", 0.0, "run launch.dryrun --all first"))
    return rows
