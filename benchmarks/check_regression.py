"""Benchmark regression gate: measured rows vs the committed baseline.

CI runs `serve_latency.py --smoke --json serve_latency.json`, uploads
the JSON as an artifact (the start of a perf trajectory across PRs), and
then gates the metrics named in `benchmarks/baseline.json`.  A baseline
entry is either a bare number (a lower-is-better CEILING: fail past
`factor` × baseline) or `{"value": v, "gate": "floor"|"ceiling"}` — a
`floor` metric is higher-is-better (throughput, utilization) and fails
BELOW baseline / `factor`.  The default 2x factor is generous on
purpose — shared CI runners are noisy; the gate exists to catch
order-of-magnitude regressions like an accidental re-compile per request
or a kernel utilization collapsing to zero, not 10% drift.  Only
load-robust metrics belong in the baseline: the deadline row's p99 rides
on real-clock scheduler wakeups and swings 10x with CPU contention (its
behavior is asserted by `--smoke` instead), while pow2 p99, flip_ms,
failover_ms, and the kernels row's utilization_frac stay within ~2x
under a fully loaded host.

Measured rows/metrics with NO baseline entry are printed as
"new row, no gate" / "new metric, no gate" — informational, never a
failure and never silently dropped, so a freshly added benchmark row is
visible on its first CI run and gating it later is just a baseline.json
entry.

Run: python benchmarks/check_regression.py measured.json \
         benchmarks/baseline.json [--factor 2.0]
Exit code 1 on any regression; prints a comparison table either way.
"""

from __future__ import annotations

import argparse
import json
import sys


def parse_gate(base) -> tuple:
    """Baseline entry -> (value, direction).  Bare numbers keep the
    historical lower-is-better ceiling; dict entries name their direction."""
    if isinstance(base, dict):
        direction = base.get("gate", "ceiling")
        if direction not in ("floor", "ceiling"):
            raise ValueError(f"unknown gate direction {direction!r}")
        return float(base["value"]), direction
    return float(base), "ceiling"


def gate_ok(got: float, base: float, direction: str, factor: float) -> tuple:
    """(passed, limit): ceiling fails past factor×base, floor below base/factor."""
    if direction == "floor":
        limit = base / factor
        return got >= limit, limit
    limit = factor * base
    return got <= limit, limit


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("measured", help="JSON written by serve_latency --json")
    ap.add_argument("baseline", help="committed benchmarks/baseline.json")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="fail past factor x baseline (default 2.0)")
    ap.add_argument("--analysis", metavar="FILE",
                    help="`repro.analysis --format json` report; injected as "
                    "an 'analysis/findings' row so finding-count creep is "
                    "visible on the same trajectory as the latency rows")
    ap.add_argument("--kernel-resources", metavar="FILE",
                    help="`python -m repro.kernels.resource_model --json` "
                    "rows; merged into the measured set so each kernel's "
                    "static VMEM bytes are CEILING-gated per baseline.json "
                    "(the repo's analogue of the paper's resource table)")
    ap.add_argument("--only", action="append", metavar="ROW",
                    help="gate only these baseline rows (repeatable) — for "
                    "runs that legitimately measure a subset, e.g. the "
                    "kernels CI job gating serve_latency/kernels from a "
                    "--backend pallas run that skips the fleet rows")
    args = ap.parse_args()

    with open(args.measured) as f:
        measured = {row["name"]: row for row in json.load(f)}
    with open(args.baseline) as f:
        baseline = json.load(f)
    if args.only:
        unknown = sorted(set(args.only) - set(baseline))
        if unknown:
            print(f"--only names absent from baseline: {unknown}",
                  file=sys.stderr)
            return 2
        baseline = {k: v for k, v in baseline.items() if k in args.only}

    if args.analysis:
        with open(args.analysis) as f:
            ana = json.load(f)
        # `findings_new` is gated at 0 via baseline.json (any un-baselined
        # finding is a regression); `findings_total`/`findings_baselined`
        # ride along ungated — grandfathering an exception must not fail
        # the latency gate, but its count should stay visible.
        measured["analysis/findings"] = {
            "name": "analysis/findings",
            "findings_new": int(ana.get("new", 0)),
            "findings_total": int(ana.get("total", 0)),
            "findings_baselined": int(ana.get("baselined", 0)),
        }

    if args.kernel_resources:
        with open(args.kernel_resources) as f:
            for row in json.load(f):
                measured[row["name"]] = row

    failures = []
    print(f"{'row':<40} {'metric':<14} {'measured':>12} {'baseline':>12} "
          f"{'limit':>12}  verdict")
    for name, metrics in sorted(baseline.items()):
        row = measured.get(name)
        if row is None:
            failures.append(f"{name}: row missing from measured output")
            print(f"{name:<40} {'-':<14} {'MISSING':>12}")
            continue
        for metric, base_entry in sorted(metrics.items()):
            got = row.get(metric)
            if got is None or not isinstance(got, (int, float)):
                failures.append(f"{name}: metric {metric!r} missing")
                print(f"{name:<40} {metric:<14} {'MISSING':>12}")
                continue
            base, direction = parse_gate(base_entry)
            ok, limit = gate_ok(float(got), base, direction, args.factor)
            verdict = "ok" if ok else "REGRESSION"
            if direction == "floor":
                verdict += " (floor)" if ok else ""
            print(f"{name:<40} {metric:<14} {float(got):>12.4f} "
                  f"{base:>12.4f} {limit:>12.4f}  {verdict}")
            if not ok:
                cmp = "<" if direction == "floor" else ">"
                failures.append(
                    f"{name}.{metric} = {got:.4f} {cmp} {direction} limit "
                    f"{limit:.4f} ({args.factor:g}x of baseline {base:.4f})")
    # rows/metrics measured but absent from the baseline are REPORTED,
    # never gated and never silently dropped: a freshly added benchmark
    # row shows up here on its first CI run, and committing a baseline
    # entry for it later turns the gate on — no ordering dance between
    # "add the row" and "hand-edit baseline.json".
    for name, row in sorted(measured.items()):
        gated = baseline.get(name)
        new_metrics = sorted(
            k for k, v in row.items()
            if k != "name" and isinstance(v, (int, float))
            and not isinstance(v, bool)
            and (gated is None or k not in gated))
        label = "new row, no gate" if gated is None else "new metric, no gate"
        for metric in new_metrics:
            print(f"{name:<40} {metric:<14} {float(row[metric]):>12.2f} "
                  f"{'-':>12} {'-':>12}  {label}")
    if failures:
        print("\nregression gate FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  - {f_}", file=sys.stderr)
        return 1
    print("\nregression gate ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
