"""DR-unit throughput: update/transform μs per call, jnp vs Pallas path.

NOTE: this container is CPU-only; the Pallas path runs in interpret mode,
so kernel timings here measure CORRECTNESS-path overhead, not TPU speed —
TPU projections come from the roofline tables instead.  The jnp numbers
are still useful as relative-throughput regressions.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import dr_unit


def _bench(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(fast: bool = True):
    rows = []
    for (m, p, n, block) in ((32, 16, 8, 32), (1024, 256, 128, 256)):
        cfg = dr_unit.DRConfig(kind="rp_easi", m=m, p=p, n=n, mu=2e-4,
                               block_size=block)
        st = dr_unit.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (block, m), jnp.float32)

        upd = jax.jit(lambda s, xb: dr_unit.update(s, cfg, xb))
        tfm = jax.jit(lambda s, xb: dr_unit.transform(s, cfg, xb))
        rows.append((f"throughput/update_m{m}", _bench(upd, st, x),
                     f"block={block};tokens_per_call={block}"))
        rows.append((f"throughput/transform_m{m}", _bench(tfm, st, x), ""))
    return rows
