"""DR-model throughput: update/transform μs per call, per execution backend.

NOTE: this container is CPU-only; the Pallas path runs in interpret mode,
so kernel timings here measure CORRECTNESS-path overhead, not TPU speed —
TPU projections come from the roofline tables instead.  The XLA numbers
are still useful as relative-throughput regressions.

Models are built once per (shape, backend) with the backend resolved in
the `Execution` policy — no per-call flags on the hot path — and the
vmapped ensemble row shows k models training in one fused pass.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.dr import DRModel, EASIStage, Execution, RPStage


def _bench(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _model(m, p, n, block, backend):
    return DRModel(
        stages=(RPStage(m, p), EASIStage.rotation(p, n, mu=2e-4)),
        execution=Execution(backend=backend), block_size=block)


def run(fast: bool = True):
    rows = []
    for (m, p, n, block) in ((32, 16, 8, 32), (1024, 256, 128, 256)):
        x = jax.random.normal(jax.random.PRNGKey(1), (block, m), jnp.float32)
        # interpret-mode Pallas is minutes-slow at the large shape on CPU;
        # bench it only where it terminates quickly
        backends = ("xla", "pallas") if m <= 32 else ("xla",)
        for backend in backends:
            model = _model(m, p, n, block, backend)
            st = model.init(jax.random.PRNGKey(0))
            upd = jax.jit(model.update)
            tfm = jax.jit(model.transform)
            iters = 20 if backend == "xla" else 5
            tag = f"_{backend}" if backend != "xla" else ""
            rows.append((f"throughput/update_m{m}{tag}",
                         _bench(upd, st, x, iters=iters),
                         f"block={block};tokens_per_call={block};backend={backend}"))
            rows.append((f"throughput/transform_m{m}{tag}",
                         _bench(tfm, st, x, iters=iters), f"backend={backend}"))

    # ensemble: k independent models, one vmapped update
    k = 8
    model = _model(32, 16, 8, 32, "xla")
    ens = model.ensemble(k)
    est = ens.init(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 32), jnp.float32)
    upd = jax.jit(ens.update)
    rows.append((f"throughput/ensemble{k}_update_m32", _bench(upd, est, x),
                 f"k={k};block=32"))
    return rows
