"""ICA recovery quality: Amari distance vs block size / estimator variant.

Quantifies the TPU adaptation claim — the block-averaged EASI estimator
(block ≥ 8) matches per-sample (paper-exact) separation quality.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import easi
from repro.data import mixtures


def run(fast: bool = True):
    n_samples = 20000 if fast else 60000
    x, a, _ = mixtures.mixture(n_samples=n_samples, m=6, n_src=6, seed=0,
                               kinds=["uniform", "bimodal", "sine"])
    x, a = jnp.asarray(x), jnp.asarray(a)
    rows = []
    for block, epochs in ((1, 2), (8, 6), (32, 16), (256, 64)):
        cfg = easi.EASIConfig(m=6, n=6, mu=2e-3)
        b0 = easi.init_b(jax.random.PRNGKey(1), cfg)
        t0 = time.perf_counter()
        b = easi.easi_fit(b0, x, cfg, block_size=block, epochs=epochs if not fast else max(2, epochs // 2))
        amari = float(easi.amari_distance(b, a))
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"ica/amari_block{block}", dt, f"amari={amari:.4f}"))
    return rows
