"""Paper Table II benchmark: hardware-cost model, EASI vs RP→EASI.

The paper reports FPGA resources (DSPs/ALMs/registers).  On TPU the
equivalent budget currencies are MACs (→ MXU FLOPs), parameter bytes
(→ HBM traffic) and — the paper's headline — their scaling in m/p.
We reproduce the claimed "factor of two" for the paper's (32, 16, 8) row
and sweep m/p to show the general law, plus the int8-vs-f32 storage ratio
the ternary alphabet buys on TPU.

Costs come straight off the stage graph: each `Stage` reports its own
Table-II numbers and `DRModel.mac_counts()` aggregates the cascade —
including chains the old kind enum could not express (3-stage row below).

Paper Table II reference (m=32, n=8): EASI only — 4052 DSPs / 38122 ALMs /
138368 reg-bits;  RP(16)+EASI — 2212 / 70031 / 75392  (≈2× DSPs+registers).
"""

from __future__ import annotations

import time

from repro.core.random_projection import RPConfig
from repro.dr import DRModel, EASIStage, RPStage


def cost_row(model: DRModel) -> dict:
    mac = model.mac_counts()
    out = {
        "rp_adds_per_sample": mac["rp_adds"],
        "easi_macs_per_sample": mac["easi_macs"],
        "total_mac_equiv": mac["rp_adds"] + mac["easi_macs"],
    }
    exe = model.execution
    rp_bytes_int8 = rp_bytes_f32 = 0
    weight_bytes = 0
    for stage in model.stages:
        if isinstance(stage, RPStage):
            cfg = stage.rp_cfg(exe)
            rp_bytes_int8 += cfg.bytes_int8()
            rp_bytes_f32 += cfg.bytes_f32()
        elif isinstance(stage, EASIStage):
            # weight bytes of the adaptive stage (FPGA register pressure analog)
            weight_bytes += 4 * stage.n * stage.m
    if rp_bytes_int8:
        out["rp_bytes_int8"] = rp_bytes_int8
        out["rp_bytes_f32"] = rp_bytes_f32
    out["easi_weight_bytes_f32"] = weight_bytes
    return out


def _easi(m, n):
    return DRModel(stages=(EASIStage.full(m, n),))


def _chain(m, p, n):
    return DRModel(stages=(RPStage(m, p), EASIStage.rotation(p, n)))


def run(fast: bool = True):
    rows = []
    t0 = time.perf_counter()

    # the paper's Table II pair
    ce, cc = cost_row(_easi(32, 8)), cost_row(_chain(32, 16, 8))
    ratio_mac = ce["easi_macs_per_sample"] / cc["easi_macs_per_sample"]
    ratio_w = ce["easi_weight_bytes_f32"] / cc["easi_weight_bytes_f32"]
    rows.append(("table2/mac_ratio_paper_row", 0.0,
                 f"easi={ce['easi_macs_per_sample']:.0f};chain={cc['easi_macs_per_sample']:.0f};"
                 f"ratio={ratio_mac:.2f};paper_dsp_ratio={4052/2212:.2f}"))
    rows.append(("table2/weight_bytes_ratio", 0.0,
                 f"ratio={ratio_w:.2f};paper_reg_ratio={138368/75392:.2f}"))

    # scaling law: savings ∝ m/p (paper §V-C)
    full = ce["easi_macs_per_sample"]
    for p in (24, 16, 8):
        r = full / cost_row(_chain(32, p, 8))["easi_macs_per_sample"]
        rows.append((f"table2/scaling_p{p}", 0.0, f"m_over_p={32/p:.2f};mac_ratio={r:.2f}"))

    # beyond the enum: a 3-stage cascade's aggregate cost vs its 2-stage peers
    cascade = DRModel(stages=(RPStage(32, 24), EASIStage.whiten(24, 16),
                              EASIStage.rotation(16, 8)))
    c3 = cost_row(cascade)
    rows.append(("table2/cascade_3stage", 0.0,
                 f"macs={c3['easi_macs_per_sample']:.0f};adds={c3['rp_adds_per_sample']:.0f};"
                 f"stages={len(cascade.stages)}"))

    # TPU adaptation: ternary int8 storage vs dense f32 (HBM-traffic analog)
    for m, p in ((1024, 256), (4096, 512)):
        rp = RPConfig(m=m, p=p)
        rows.append((f"table2/int8_storage_m{m}", 0.0,
                     f"int8={rp.bytes_int8()};f32={rp.bytes_f32()};ratio={rp.bytes_f32()/rp.bytes_int8():.1f}"))

    dt = (time.perf_counter() - t0) * 1e6
    rows = [(n, dt / max(len(rows), 1), d) for n, _, d in rows]
    return rows
