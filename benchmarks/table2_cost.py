"""Paper Table II benchmark: hardware-cost model, EASI vs RP→EASI.

The paper reports FPGA resources (DSPs/ALMs/registers).  On TPU the
equivalent budget currencies are MACs (→ MXU FLOPs), parameter bytes
(→ HBM traffic) and — the paper's headline — their scaling in m/p.
We reproduce the claimed "factor of two" for the paper's (32, 16, 8) row
and sweep m/p to show the general law, plus the int8-vs-f32 storage ratio
the ternary alphabet buys on TPU.

Paper Table II reference (m=32, n=8): EASI only — 4052 DSPs / 38122 ALMs /
138368 reg-bits;  RP(16)+EASI — 2212 / 70031 / 75392  (≈2× DSPs+registers).
"""

from __future__ import annotations

import time

from repro.core.dr_unit import DRConfig
from repro.core.random_projection import RPConfig


def cost_row(cfg: DRConfig) -> dict:
    mac = cfg.mac_counts()
    out = {
        "rp_adds_per_sample": mac["rp_adds"],
        "easi_macs_per_sample": mac["easi_macs"],
        "total_mac_equiv": mac["rp_adds"] + mac["easi_macs"],
    }
    if cfg.rp_cfg is not None:
        rp: RPConfig = cfg.rp_cfg
        out["rp_bytes_int8"] = rp.bytes_int8()
        out["rp_bytes_f32"] = rp.bytes_f32()
    # weight bytes of the adaptive stage (the FPGA register pressure analog)
    e = cfg.easi_cfg
    out["easi_weight_bytes_f32"] = 4 * e.n * e.m if e else 0
    return out


def run(fast: bool = True):
    rows = []
    t0 = time.perf_counter()

    # the paper's Table II pair
    easi = DRConfig(kind="easi", m=32, n=8)
    chain = DRConfig(kind="rp_easi", m=32, p=16, n=8)
    ce, cc = cost_row(easi), cost_row(chain)
    ratio_mac = ce["easi_macs_per_sample"] / cc["easi_macs_per_sample"]
    ratio_w = ce["easi_weight_bytes_f32"] / cc["easi_weight_bytes_f32"]
    rows.append(("table2/mac_ratio_paper_row", 0.0,
                 f"easi={ce['easi_macs_per_sample']:.0f};chain={cc['easi_macs_per_sample']:.0f};"
                 f"ratio={ratio_mac:.2f};paper_dsp_ratio={4052/2212:.2f}"))
    rows.append(("table2/weight_bytes_ratio", 0.0,
                 f"ratio={ratio_w:.2f};paper_reg_ratio={138368/75392:.2f}"))

    # scaling law: savings ∝ m/p (paper §V-C)
    for p in (24, 16, 8):
        c = DRConfig(kind="rp_easi", m=32, p=p, n=8)
        r = cost_row(easi)["easi_macs_per_sample"] / cost_row(c)["easi_macs_per_sample"]
        rows.append((f"table2/scaling_p{p}", 0.0, f"m_over_p={32/p:.2f};mac_ratio={r:.2f}"))

    # TPU adaptation: ternary int8 storage vs dense f32 (HBM-traffic analog)
    for m, p in ((1024, 256), (4096, 512)):
        rp = RPConfig(m=m, p=p)
        rows.append((f"table2/int8_storage_m{m}", 0.0,
                     f"int8={rp.bytes_int8()};f32={rp.bytes_f32()};ratio={rp.bytes_f32()/rp.bytes_int8():.1f}"))

    dt = (time.perf_counter() - t0) * 1e6
    rows = [(n, dt / max(len(rows), 1), d) for n, _, d in rows]
    return rows
