"""Serving-engine latency: open-loop synthetic load vs batch-bucket policy.

Protocol (EXPERIMENTS.md §Serving): a ragged request stream (lognormal row
counts, fixed seed) is submitted to a `DRService` in fixed-size admission
windows — open-loop: the window arrives regardless of service progress —
then `flush()` coalesces each window into bucketed micro-batches.  Per
request we record submit→result wall time; rows report p50/p99 latency,
steady-state throughput, the compile count, and the padding overhead for
each bucket policy:

  pow2     — powers-of-two padding (the engine default): O(log max/min)
             compiled programs, some padded rows.
  exact    — no coalescing headroom (`batching.EXACT`), the pre-engine
             behavior: one compiled program per distinct request size.
  deadline — pow2 buckets behind the `DeadlineScheduler` event loop: no
             explicit flush at all; each request carries `max_delay_ms`
             and the loop flushes on fill-or-deadline.  Reports the
             deadline-miss rate next to the same compile count as pow2
             (deadline flushes reuse the bucketed programs).

A train-while-serve row exercises the full register → serve_and_update →
promote → transform round trip on the same stream.

`--backend pallas` reruns the backend-dependent rows (pow2, train-while-
serve) with the model registered under `Execution(backend="pallas")` —
the bucketed transform dispatches to the fused pad+project+whiten Pallas
kernel and the streamed updates to `kernels.ops.easi_update`, autotuned
per bucket at register time.  Those rows are suffixed `@pallas` so the
XLA baselines don't mis-gate them; the exact/deadline and fleet rows are
backend-independent and are skipped.

A kernels row (emitted under EVERY backend flag) is the roofline judge:
it serves bucket-shaped batches through an autotuned pallas service,
converts best-of wall times to achieved FLOP/s (model FLOPs: 2mp + 2pn
per row — the paper's project-then-whiten datapath), and reports
`utilization_frac` against `repro.launch.roofline.device_peak_flops()`
(datasheet peak on TPU, measured dense-matmul peak elsewhere).  That
metric is FLOOR-gated in `benchmarks/baseline.json`: a broken kernel
dispatch or a silent fall-back to per-row serving shows up as
utilization collapsing toward zero.

A replicated-promote row runs a 3-host `LocalBus` fleet (one leader +
two follower `ReplicatedRegistry`s, each behind its own `DRService`) and
measures the two-phase flip: `flip_ms` is time-to-consistency (promote
call → every host uniformly on the new version, i.e. quorum-ack on the
synchronous bus), while reader threads hammering the follower engines
count how many requests were answered against the stale version during
the flip window.

A failover row runs the same fleet with an `Elector` per host (real
`MonotonicClock`, loopless polling) and measures `failover_ms`: the time
from killing the leader to the FIRST successful promote on the newly
elected leader — the fleet-availability number the election layer exists
to bound (≈ election timeout + one vote round + one two-phase flip).

A durability row builds a solo durable host (`ReplicatedRegistry` with
`data_dir=`), pushes a stack of versions, promotes, compacts, then cold
restarts from disk: `restore_ms` is the full bootstrap (WAL scan + torn
tail truncate + snapshot load + op replay) and `snapshot_bytes` the
compacted on-disk footprint.

Three fleet-merge rows (`fleet_merge_{1,8,32}x`) run a 3-host fleet with
a `FleetMerger` per host: every host streams a disjoint shard through
`serve_and_update`, then the leader drives one compressed delta-merge
round end to end.  `merge_wall_ms` is the warm round (collect + sketch
all-reduce + projection decode + quorum promote + commit) and
`wire_bytes` what actually crossed the bus — both CEILING-gated, so a
compression regression (sketches silently riding the raw path) or a
merge-path slowdown fails CI's fleet-merge job.

`--json out.json` additionally writes the rows machine-readably (the
`derived` k=v pairs parsed into fields); CI uploads that artifact and
gates `flip_ms` / `p99_us` / `failover_ms` / `restore_ms` /
`snapshot_bytes` / `merge_wall_ms` / `wire_bytes` against
`benchmarks/baseline.json` at a generous 2x via
`benchmarks/check_regression.py`.

Run: PYTHONPATH=src python benchmarks/serve_latency.py [--smoke] [--full]
[--json out.json] (or through `python -m benchmarks.run --only
serve_latency`).
"""

from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.execution import Execution
from repro.dist.compress import CompressConfig, collective_bytes_saved
from repro.dr import DRModel, EASIStage, RPStage
from repro.launch import roofline
from repro.serve import (BucketPolicy, DRService, DeadlineScheduler, Elector,
                         FleetMerger, LocalBus, ReplicatedRegistry,
                         ReplicationError, state_hash)
from repro.serve.batching import EXACT


def _model(m=32, p=16, n=8, block=8, backend="xla"):
    return DRModel(stages=(RPStage(m, p), EASIStage.rotation(p, n, mu=5e-4)),
                   execution=Execution(backend=backend), block_size=block)


def _requests(n_req: int, m: int, *, seed: int = 0, max_rows: int = 48):
    """Ragged synthetic load: lognormal row counts in [1, max_rows]."""
    rng = np.random.RandomState(seed)
    sizes = np.clip(np.rint(rng.lognormal(mean=1.6, sigma=0.9, size=n_req)),
                    1, max_rows).astype(int)
    return [jnp.asarray(rng.randn(s, m).astype(np.float32)) for s in sizes]


def _drive(svc: DRService, name: str, reqs, window: int, *,
           direct: bool = False, scheduler: DeadlineScheduler = None):
    """Submit in open-loop windows; returns per-request latencies (s) and
    the wall time of the measured phase.  `direct=True` bypasses the
    micro-batcher — one device step per request, the pre-engine serving
    shape.  With `scheduler`, nothing ever calls flush(): the deadline
    loop answers, and the driver just waits on the tickets."""
    lat = []
    t_start = time.perf_counter()
    for w0 in range(0, len(reqs), window):
        batch = reqs[w0:w0 + window]
        if direct:
            for x in batch:
                s = time.perf_counter()
                jax.block_until_ready(svc.transform(name, x))
                lat.append(time.perf_counter() - s)
            continue
        submit_t, tickets = [], []
        for x in batch:
            submit_t.append(time.perf_counter())
            tickets.append(scheduler.submit(name, x) if scheduler is not None
                           else svc.submit(name, x))
        if scheduler is None:
            svc.flush()
        for t in tickets:
            if scheduler is not None:
                t.wait(30.0)
            jax.block_until_ready(t.result())
        done = time.perf_counter()
        lat.extend(done - s for s in submit_t)
    return np.asarray(lat), time.perf_counter() - t_start


def run(fast: bool = True, backend: str = "xla"):
    n_req = 64 if fast else 512
    window = 8
    suffix = "" if backend == "xla" else f"@{backend}"
    model = _model(backend=backend)
    state = model.init(jax.random.PRNGKey(0))
    reqs = _requests(n_req, model.in_dim)
    total_rows = int(sum(r.shape[0] for r in reqs))

    rows = []
    policies = (("pow2", BucketPolicy(min_bucket=4, max_bucket=64)),
                ("exact", EXACT),
                ("deadline", BucketPolicy(min_bucket=4, max_bucket=64)))
    if suffix:
        # non-default backends rerun only the backend-dependent rows: exact
        # compiles one interpret-mode kernel per distinct request size (an
        # unbounded universe — pointless and slow), and the deadline row is
        # a real-clock scheduler benchmark, independent of the datapath
        policies = policies[:1]
    for tag, policy in policies:
        direct = policy.exact
        svc = DRService(buckets=policy, compile_cache_size=128)
        svc.register("dr", model, state)
        sched = DeadlineScheduler(svc, default_max_delay_ms=2.0,
                                  wake_lead_ms=1.0) \
            if tag == "deadline" else None
        _drive(svc, "dr", reqs, window, direct=direct,
               scheduler=sched)                          # warmup: pay compiles
        compiles = svc.cache.misses
        met0, missed0 = svc.slo.deadline_counts()
        lat, wall = _drive(svc, "dr", reqs, window, direct=direct,
                           scheduler=sched)
        met = svc.metrics()
        p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
        pad_frac = met["padded_rows"] / max(1, met["padded_rows"] + met["served_rows"])
        derived = (f"p99_us={p99 * 1e6:.1f};rows_per_s={total_rows / wall:.0f};"
                   f"compiles={compiles};padded_frac={pad_frac:.3f};"
                   f"batches={met['batches_run']}")
        if sched is not None:
            got, missed = (met["deadline_met"] - met0,
                           met["deadline_missed"] - missed0)
            derived += (f";deadline_miss_rate="
                        f"{missed / max(1, got + missed):.3f}")
            sched.shutdown()
        rows.append((f"serve_latency/{tag}{suffix}", p50 * 1e6, derived))

    # train-while-serve: the full round trip on the same stream
    svc = DRService(buckets=BucketPolicy(min_bucket=4, max_bucket=64))
    svc.register("dr", model, state)
    bs = model.block_size
    stream = jnp.concatenate(reqs, axis=0)
    blocks = stream[: (stream.shape[0] // bs) * bs].reshape(-1, bs, model.in_dim)
    t0 = time.perf_counter()
    for blk in blocks:
        jax.block_until_ready(svc.serve_and_update("dr", blk))
    wall = time.perf_counter() - t0
    v = svc.promote("dr")
    y = svc.transform("dr", reqs[0])
    assert bool(jnp.isfinite(y).all()) and v == 1
    rows.append((f"serve_latency/train_while_serve{suffix}",
                 wall / max(1, len(blocks)) * 1e6,
                 f"blocks={len(blocks)};promoted_version={v};"
                 f"updates={svc.metrics()['updates_applied']['dr']}"))

    # the roofline judge rides every backend flag: it builds its own
    # pallas service either way (gated by the same floor in baseline.json)
    rows.append(_kernels_row(fast))
    if suffix:
        return rows     # fleet + durability rows are backend-independent

    # replicated promote: 3-host fleet, two-phase flip under live traffic
    bus = LocalBus()
    leader = ReplicatedRegistry(bus.attach("h0"), role="leader")
    regs = [leader] + [ReplicatedRegistry(bus.attach(f"h{i}"),
                                          role="follower", leader="h0")
                       for i in (1, 2)]
    svcs = [DRService(registry=r,
                      buckets=BucketPolicy(min_bucket=4, max_bucket=64))
            for r in regs]
    leader.register("dr", model, state)
    retrained = model.fit(state, stream[:256], epochs=1)
    v = leader.push("dr", retrained)                 # replicated, NOT live
    x_probe = reqs[0]
    for s in svcs:                                   # warm every host's jit
        jax.block_until_ready(s.transform("dr", x_probe))
    lock = threading.Lock()
    samples = []                                     # (snapshot time, version)
    stop = threading.Event()

    def reader(s):
        while not stop.is_set():
            t_read = time.perf_counter()
            served_v = s.registry.get("dr").version  # epoch this request sees
            jax.block_until_ready(s.transform("dr", x_probe))
            with lock:
                samples.append((t_read, served_v))

    readers = [threading.Thread(target=reader, args=(s,)) for s in svcs[1:]]
    for th in readers:
        th.start()
    t0 = time.perf_counter()
    leader.promote("dr", v)                          # two-phase fleet flip
    t1 = time.perf_counter()
    flip_ms = (t1 - t0) * 1e3
    finals = [r.get("dr").version for r in regs]
    stop.set()
    for th in readers:
        th.join(30.0)
    # only requests whose SNAPSHOT landed inside [promote start, quorum-ack]
    # count toward the flip window — anything earlier legitimately serves old
    window = [v_ for t, v_ in samples if t0 <= t <= t1]
    stale = sum(1 for v_ in window if v_ == 0)
    rows.append(("serve_latency/replicated_promote", flip_ms * 1e3,
                 f"hosts=3;flip_ms={flip_ms:.2f};"
                 f"stale_served_during_flip={stale};"
                 f"reads_during_flip_window={len(window)};"
                 f"final_versions={'/'.join(map(str, finals))}"))

    # failover: kill the leader, elect, first successful promote on the
    # winner.  Electors run loopless on the REAL clock (this is a wall-time
    # benchmark): the driver polls them the way a background loop would.
    bus = LocalBus()
    leader = ReplicatedRegistry(bus.attach("h0"), role="leader")
    regs = [leader] + [ReplicatedRegistry(bus.attach(f"h{i}"),
                                          role="follower", leader="h0")
                       for i in (1, 2)]
    electors = [Elector(r, seed=i, election_timeout_ms=(30.0, 60.0),
                        heartbeat_interval_ms=10.0)
                for i, r in enumerate(regs)]
    leader.register("dr", model, state)
    v = leader.push("dr", retrained)            # committed fleet-wide
    bus.partition("h0")                         # the leader dies
    t0 = time.perf_counter()
    deadline = t0 + 30.0
    new_v = None
    while time.perf_counter() < deadline:
        for e in electors[1:]:
            e.poll()
        cands = [r for r in regs[1:] if r.role == "leader"]
        if not cands:
            time.sleep(1e-3)
            continue
        try:
            new_v = cands[0].promote("dr", v)   # first promote on the winner
            break
        except ReplicationError:
            time.sleep(1e-3)                    # vote round still settling
    failover_ms = (time.perf_counter() - t0) * 1e3
    assert new_v == v, "failover benchmark never promoted on a new leader"
    winners = [r.transport.host_id for r in regs[1:] if r.role == "leader"]
    term = max(r.term for r in regs[1:])
    finals = sorted(r.get("dr").version for r in regs[1:])
    rows.append(("serve_latency/failover", failover_ms * 1e3,
                 f"hosts=3;failover_ms={failover_ms:.2f};"
                 f"winner={winners[0]};term={term};"
                 f"final_versions={'/'.join(map(str, finals))}"))

    # durability: WAL + blobs + compacted snapshot on a solo durable host,
    # then a cold restart from disk.  `restore_ms` is the full bootstrap
    # (open WAL, truncate any torn tail, load snapshot, replay ops through
    # the registry) and `snapshot_bytes` the total on-disk footprint after
    # compaction — both gated at 2x against baseline.json.
    n_states = 8 if fast else 32
    data_dir = tempfile.mkdtemp(prefix="serve-durability-")
    try:
        reg = ReplicatedRegistry(LocalBus().attach("h0"), role="leader",
                                 quorum=1, data_dir=data_dir)
        reg.register("dr", model, state)
        v = 0
        for i in range(1, n_states):
            v = reg.push("dr", model.init(jax.random.PRNGKey(i)))
        reg.promote("dr", v)
        reg.compact()
        want_hash = state_hash(reg.get("dr").state)
        snapshot_bytes = reg.durable.size_bytes()
        del reg                                     # crash: no close
        t0 = time.perf_counter()
        reg2 = ReplicatedRegistry(LocalBus().attach("h0"), role="leader",
                                  quorum=1, data_dir=data_dir)
        restore_ms = (time.perf_counter() - t0) * 1e3
        restored_v = reg2.get("dr").version
        assert state_hash(reg2.get("dr").state) == want_hash, \
            "durability benchmark restored different bytes"
        rows.append(("serve_latency/durability", restore_ms * 1e3,
                     f"restore_ms={restore_ms:.2f};"
                     f"snapshot_bytes={snapshot_bytes};"
                     f"versions={n_states};restored_version={restored_v}"))
    finally:
        shutil.rmtree(data_dir, ignore_errors=True)

    # fleet merge: 3 hosts stream DISJOINT shards through serve_and_update,
    # then the leader runs one compressed delta-merge round per ratio
    # (collect -> sketch all-reduce -> projection decode -> quorum promote
    # -> commit).  `merge_wall_ms` is the full round on a warm fleet;
    # `wire_bytes` is what actually crossed the bus (round report) and
    # `sketch_ratio` the accounting from `collective_bytes_saved` — the
    # wall time and wire bytes are gated 2x per ratio in baseline.json.
    bs = model.block_size
    n_blocks = 6 if fast else 24
    for ratio in (1, 8, 32):
        cfg = CompressConfig(ratio=ratio, min_size=64)
        bus = LocalBus()
        leader = ReplicatedRegistry(bus.attach("h0"), role="leader")
        regs = [leader] + [ReplicatedRegistry(bus.attach(f"h{i}"),
                                              role="follower", leader="h0")
                           for i in (1, 2)]
        svcs = [DRService(registry=r,
                          buckets=BucketPolicy(min_bucket=4, max_bucket=64))
                for r in regs]
        mergers = [FleetMerger(s, compress_cfg=cfg) for s in svcs]
        leader.register("dr", model, state)
        rng = np.random.RandomState(11 + ratio)

        def _feed():
            for si, s in enumerate(svcs):
                for _ in range(n_blocks):
                    blk = jnp.asarray(
                        rng.randn(bs, model.in_dim).astype(np.float32)
                        + 0.25 * si)
                    jax.block_until_ready(s.serve_and_update("dr", blk))

        _feed()
        mergers[0].merge_round("dr")    # warmup: pay the sketch-path jits
        _feed()
        rep = mergers[0].merge_round("dr")
        assert rep["version"] is not None and len(rep["contributors"]) == 3, rep
        acct = collective_bytes_saved(state, cfg)
        rows.append((f"serve_latency/fleet_merge_{ratio}x",
                     rep["wall_ms"] * 1e3,
                     f"hosts=3;ratio={ratio};"
                     f"merge_wall_ms={rep['wall_ms']:.2f};"
                     f"wire_bytes={rep['bytes_sketched']};"
                     f"uncompressed_bytes={rep['bytes_uncompressed']};"
                     f"sketch_ratio={acct['ratio']:.2f};"
                     f"contributors={len(rep['contributors'])};"
                     f"updates_folded={rep['updates_folded']};"
                     f"version={rep['version']}"))
    return rows


def _kernels_row(fast: bool):
    """Roofline judge (EXPERIMENTS.md §Kernels): achieved FLOP/s of the
    autotuned fused serve transform per bucket vs the device peak.

    Model FLOPs per served row are the paper datapath's useful work —
    2mp (ternary project) + 2pn (whiten/rotate map) — the same
    model-vs-achieved accounting as SNIPPETS.md's MODEL_FLOPS_PER_SAMPLE
    tables.  `utilization_frac` is the best bucket's achieved/peak; it is
    floor-gated so a dispatch that silently stops reaching the kernel (or
    an autotuner that stops running) fails CI rather than flattering it."""
    m, p, n = 32, 16, 8
    model = _model(m, p, n, backend="pallas")
    state = model.init(jax.random.PRNGKey(0))
    buckets = (16, 64) if fast else (16, 64, 256)
    svc = DRService(buckets=BucketPolicy(min_bucket=buckets[0],
                                         max_bucket=buckets[-1]),
                    compile_cache_size=64)
    svc.register("dr", model, state)            # register-time tile sweep
    flops_per_row = 2 * m * p + 2 * p * n
    peak, peak_src = roofline.device_peak_flops()
    rng = np.random.RandomState(0)
    best_util, parts, t_best = 0.0, [], float("inf")
    for b in buckets:
        x = jnp.asarray(rng.randn(b, m).astype(np.float32))
        jax.block_until_ready(svc.transform("dr", x))       # warm
        t_best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(svc.transform("dr", x))
            t_best = min(t_best, time.perf_counter() - t0)
        achieved = b * flops_per_row / t_best
        best_util = max(best_util, achieved / peak)
        parts.append(f"gflops_b{b}={achieved / 1e9:.4f}")
    derived = (";".join(parts)
               + f";utilization_frac={best_util:.6f}"
               f";peak_gflops={peak / 1e9:.1f};peak_src={peak_src}"
               f";autotunes={svc.metrics()['autotunes']}"
               f";flops_per_row={flops_per_row}"
               f";platform={jax.default_backend()}")
    return ("serve_latency/kernels", t_best * 1e6, derived)


def _parse_derived(derived: str):
    out = {}
    for kv in derived.split(";"):
        if "=" not in kv:
            continue
        k, v = kv.split("=", 1)
        try:
            out[k] = float(v) if "." in v or "e" in v.lower() else int(v)
        except ValueError:
            out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast run + sanity assertions (CI)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", metavar="PATH",
                    help="also write machine-readable rows (CI artifact + "
                         "regression gate input)")
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla",
                    help="Execution backend the served model registers "
                         "with; pallas reruns the backend-dependent rows "
                         "through the fused kernels (rows suffixed @pallas)")
    args = ap.parse_args()

    rows = run(fast=not args.full, backend=args.backend)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        payload = [{"name": name, "us_per_call": us, **_parse_derived(d)}
                   for name, us, d in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json} ({len(payload)} rows)")

    if args.smoke:
        sfx = "" if args.backend == "xla" else f"@{args.backend}"
        by = {n: d for n, _, d in rows}
        pow2_compiles = int(by[f"serve_latency/pow2{sfx}"]
                            .split("compiles=")[1].split(";")[0])
        # the bucketed compile universe must be tiny — for pallas that
        # includes the register-time autotuned bucket programs
        assert pow2_compiles <= 6, pow2_compiles
        assert "promoted_version=1" in by[f"serve_latency/train_while_serve{sfx}"]
        # the roofline judge must have measured real kernel utilization
        # through an autotuned service — zero means the dispatch is broken
        kd = by["serve_latency/kernels"]
        util = float(kd.split("utilization_frac=")[1].split(";")[0])
        assert util > 0.0, kd
        assert int(kd.split("autotunes=")[1].split(";")[0]) >= 1, kd
        if not sfx:
            exact_compiles = int(by["serve_latency/exact"].split("compiles=")[1].split(";")[0])
            ddl_compiles = int(by["serve_latency/deadline"].split("compiles=")[1].split(";")[0])
            # bucketing must beat exact shapes
            assert pow2_compiles < exact_compiles, (pow2_compiles, exact_compiles)
            # deadline flushes reuse the same bucketed programs — no new compiles
            assert ddl_compiles <= 6, ddl_compiles
            # miss = flush STARTED past the budget; a scheduler that only ever
            # drains at shutdown would miss everything — that must not pass
            miss = float(by["serve_latency/deadline"]
                         .split("deadline_miss_rate=")[1].split(";")[0])
            assert 0.0 <= miss < 1.0, miss
            # the fleet flip must end uniformly on the new version — a mixed
            # final epoch means the two-phase promote tore the deployment
            assert "final_versions=1/1/1" in by["serve_latency/replicated_promote"]
            # failover: both SURVIVING hosts must be uniformly on the promoted
            # version, flipped by a leader elected at a real (>0) term
            assert "final_versions=1/1" in by["serve_latency/failover"]
            assert int(by["serve_latency/failover"]
                       .split("term=")[1].split(";")[0]) >= 1
            # durability: the cold restart must come back on the promoted
            # version (the content-hash identity is asserted inside run())
            dur = by["serve_latency/durability"]
            n_states = int(dur.split("versions=")[1].split(";")[0])
            restored = int(dur.split("restored_version=")[1].split(";")[0])
            assert restored == n_states - 1, (restored, n_states)
            assert int(dur.split("snapshot_bytes=")[1].split(";")[0]) > 0
        print("SERVE_LATENCY_SMOKE_OK")


if __name__ == "__main__":
    main()
