"""Benchmark driver — one module per paper table / system aspect.

Prints ``name,us_per_call,derived`` CSV rows.

  table1_accuracy — paper Table I (Waveform accuracy per DR config)
  table2_cost     — paper Table II (hardware-cost model + m/p scaling)
  ica_quality     — Amari distance vs block size (TPU estimator parity)
  throughput      — DR update/transform μs/call (CPU; kernels interpret-mode)
  serve_latency   — DRService p50/p99 + throughput vs batch-bucket policy
  roofline_table  — §Roofline rows aggregated from the dry-run JSONs

Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (ica_quality, roofline_table, serve_latency,
                        table1_accuracy, table2_cost, throughput)

SUITES = {
    "table2_cost": table2_cost,
    "ica_quality": ica_quality,
    "throughput": throughput,
    "serve_latency": serve_latency,
    "table1_accuracy": table1_accuracy,
    "roofline_table": roofline_table,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full (slow) protocol")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    for name, mod in SUITES.items():
        if args.only and name != args.only:
            continue
        try:
            for row_name, us, derived in mod.run(fast=not args.full):
                print(f"{row_name},{us:.1f},{derived}")
            sys.stdout.flush()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
